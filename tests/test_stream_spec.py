"""Executable DESIGN.md §4 — the RNG stream specification as a test.

The channel is *defined* by its random streams: every reserved fold
domain, the chunk-quantized threefry draw, and the position-determinism
slice rule are contract, not implementation detail. This suite pins all
of it in one place, parametrized over every reserved fold, so a stream
regression names the offending fold instead of surfacing as a mystery
mismatch three engines away.

What is pinned here (anything that changes a pinned value is a stream-
spec BREAK and needs a DESIGN.md §4 edit + checkpoint-migration story):

* the reserved fold VALUES themselves, and that they are pairwise
  distinct and live at/above the 0x7FFF0000 floor (structurally
  disjoint from any cluster / leaf / chunk index);
* golden first-u32 digests of the gain stream (per fold, cluster 0)
  and the noise stream (per fold) under ``jax.random.PRNGKey(0)``;
* the chunk-slice identity: ``stream_range_bits(key, a, n)`` equals the
  same positions of the whole-stream draw, across chunk boundaries;
* the section-fold schedule: trunk section s ⇒ BASE + s, the ω̃ tail
  keeps PACKED_TAIL_FOLD in every layout;
* the participation sub-folds (dropout/blackout/straggler) and the
  SAMPLE_FOLD client-id draw are disjoint from every channel stream;
* the aux-class salts (init folds, probe folds, the dist backward's
  mask/region salts — DESIGN.md §4 table, class ``aux``) with their own
  value + golden pins, and the KLASS_SALT dict's collision-freedom.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.stream_registry import is_salt_name
from repro.common.flatpack import packer_for
from repro.core import ota
from repro.core.hota import KLASS_SALT, PACKED_FINAL_FOLD, REGION_SALT
from repro.core.hota_slab import PACKED_OMEGA_FOLD

# Every reserved fold domain of DESIGN.md §4, by name. New domains MUST
# be registered here — the golden tables below force the registration.
RESERVED_FOLDS = {
    "NOISE_FOLD": ota.NOISE_FOLD,
    "PACKED_HEAD_FOLD": ota.PACKED_HEAD_FOLD,
    "PACKED_TAIL_FOLD": ota.PACKED_TAIL_FOLD,
    "SIM_CHAN_FOLD": ota.SIM_CHAN_FOLD,
    "PART_FOLD": ota.PART_FOLD,
    "SAMPLE_FOLD": ota.SAMPLE_FOLD,
    "PACKED_FINAL_FOLD": PACKED_FINAL_FOLD,
    "PACKED_OMEGA_FOLD": PACKED_OMEGA_FOLD,
    "PACKED_SECTION_FOLD_0": ota.PACKED_SECTION_FOLD_BASE + 0,
    "PACKED_SECTION_FOLD_1": ota.PACKED_SECTION_FOLD_BASE + 1,
    "PACKED_SECTION_FOLD_2": ota.PACKED_SECTION_FOLD_BASE + 2,
}

# the spec'd values — a constant that drifts is a silent re-keying of
# every checkpointed stream
FOLD_VALUES = {
    "NOISE_FOLD": 0x7FFFFFFF,
    "PACKED_HEAD_FOLD": 0x7FFF0001,
    "PACKED_TAIL_FOLD": 0x7FFF0002,
    "SIM_CHAN_FOLD": 0x7FFF0003,
    "PART_FOLD": 0x7FFF0004,
    "SAMPLE_FOLD": 0x7FFF0005,
    "PACKED_FINAL_FOLD": 0x7FFF00F1,
    "PACKED_OMEGA_FOLD": 0x7FFF00F2,
    "PACKED_SECTION_FOLD_0": 0x7FFF0100,
    "PACKED_SECTION_FOLD_1": 0x7FFF0101,
    "PACKED_SECTION_FOLD_2": 0x7FFF0102,
}

# golden first u32 of the cluster-0 gain stream under PRNGKey(0):
# stream_range_bits(section_gain_key(key, fold, 0), 0, 4)[0]
GOLDEN_GAIN_U32 = {
    "NOISE_FOLD": 0x0B686A7C,
    "PACKED_HEAD_FOLD": 0xE2E0D19F,
    "PACKED_TAIL_FOLD": 0x1BEF84B4,
    "SIM_CHAN_FOLD": 0x3A418B11,
    "PART_FOLD": 0xB89EA6A5,
    "SAMPLE_FOLD": 0xDEABE9ED,
    "PACKED_FINAL_FOLD": 0x3AEBBD34,
    "PACKED_OMEGA_FOLD": 0x755B8C4B,
    "PACKED_SECTION_FOLD_0": 0x0B3450D2,
    "PACKED_SECTION_FOLD_1": 0xAB81093C,
    "PACKED_SECTION_FOLD_2": 0x96C21E23,
}

# golden first u32 of the per-fold noise stream under PRNGKey(0):
# stream_range_bits(section_noise_key(key, fold), 0, 4)[0]
GOLDEN_NOISE_U32 = {
    "NOISE_FOLD": 0xD9CF7EC3,
    "PACKED_HEAD_FOLD": 0x32DFF2BA,
    "PACKED_TAIL_FOLD": 0xF4999DB8,
    "SIM_CHAN_FOLD": 0xE5AB619D,
    "PART_FOLD": 0x8EEA33EF,
    "SAMPLE_FOLD": 0xADDA1262,
    "PACKED_FINAL_FOLD": 0x8007622F,
    "PACKED_OMEGA_FOLD": 0x5032934A,
    "PACKED_SECTION_FOLD_0": 0xF9C4A3E8,
    "PACKED_SECTION_FOLD_1": 0x3E08D583,
    "PACKED_SECTION_FOLD_2": 0x587C0806,
}

# aux-class salts (DESIGN.md §4 table): folded off keys that never meet
# the per-round channel key domain (init keys, probe keys, sub-folds of
# an already-reserved parent), so they may be small — but they are
# registered, value-pinned, and golden-pinned all the same. The four
# *_INIT/*_PROBE/*_MASK entries are the historical bare literals the
# §3.17 lint found; registration kept their VALUES so no stream moved.
AUX_SALTS = {
    "PART_DROP_FOLD": ota.PART_DROP_FOLD,
    "PART_BLACK_FOLD": ota.PART_BLACK_FOLD,
    "PART_STRAG_FOLD": ota.PART_STRAG_FOLD,
    "FINAL_INIT_FOLD": ota.FINAL_INIT_FOLD,
    "SAMPLE_INIT_FOLD": ota.SAMPLE_INIT_FOLD,
    "TUNE_PROBE_FOLD": ota.TUNE_PROBE_FOLD,
    "REGION_SALT": REGION_SALT,
    "HOTA_MASK_SALT": ota.HOTA_MASK_SALT,
}

AUX_VALUES = {
    "PART_DROP_FOLD": 0,
    "PART_BLACK_FOLD": 1,
    "PART_STRAG_FOLD": 2,
    "FINAL_INIT_FOLD": 7,
    "SAMPLE_INIT_FOLD": 11,
    "TUNE_PROBE_FOLD": 99,
    "REGION_SALT": 0xC0,
    "HOTA_MASK_SALT": 0xBEEF,
}

# golden first u32 of bits(fold_in(PRNGKey(0), salt), (4,))[0] — the raw
# derived-key digest (aux salts have no section/noise stream schedule)
GOLDEN_AUX_U32 = {
    "PART_DROP_FOLD": 0xA93D9CF0,
    "PART_BLACK_FOLD": 0xBBE44D07,
    "PART_STRAG_FOLD": 0x369464D0,
    "FINAL_INIT_FOLD": 0xA42B7666,
    "SAMPLE_INIT_FOLD": 0x58C7EA79,
    "TUNE_PROBE_FOLD": 0x6B9484A4,
    "REGION_SALT": 0x214AA0B2,
    "HOTA_MASK_SALT": 0x47F7A328,
}

# the dist backward's per-klass region-key salts — collision-free dict
KLASS_SALT_VALUES = {"embed": 1, "layers": 2, "final": 3, "mamba": 4,
                     "shared_attn": 5, "shared_mlp": 6, "mlstm": 7,
                     "slstm": 8}

KEY = jax.random.PRNGKey(0)
FOLD_NAMES = sorted(RESERVED_FOLDS)
AUX_NAMES = sorted(AUX_SALTS)


# -------------------------------------------------------------- constants
@pytest.mark.parametrize("name", FOLD_NAMES)
def test_reserved_fold_value_pinned(name):
    assert RESERVED_FOLDS[name] == FOLD_VALUES[name], (
        f"reserved fold {name} changed: 0x{RESERVED_FOLDS[name]:08X} != "
        f"spec'd 0x{FOLD_VALUES[name]:08X} — this re-keys every stream "
        f"drawn under it (DESIGN.md §4)")


@pytest.mark.parametrize("name", FOLD_NAMES)
def test_reserved_fold_above_floor(name):
    assert RESERVED_FOLDS[name] >= 0x7FFF0000, (
        f"reserved fold {name} = 0x{RESERVED_FOLDS[name]:08X} is below "
        f"the 0x7FFF0000 reserved floor — it can collide with a cluster/"
        f"leaf/section index fold")


def test_reserved_folds_pairwise_distinct():
    for a, b in itertools.combinations(FOLD_NAMES, 2):
        assert RESERVED_FOLDS[a] != RESERVED_FOLDS[b], (
            f"reserved folds {a} and {b} collide at "
            f"0x{RESERVED_FOLDS[a]:08X} — their streams are identical")


def test_registry_is_complete():
    """Every named FOLD/SALT constant in the core modules is registered
    here, reserved or aux (new domains must land with golden digests).
    The name filter is the same ``is_salt_name`` the §3.17 lint uses, so
    a constant can't claim registry membership to the linter while
    dodging this scan (or vice versa)."""
    from repro.core import hota, hota_slab
    registered = set(RESERVED_FOLDS.values()) | set(AUX_SALTS.values())
    for mod in (ota, hota, hota_slab):
        for attr in dir(mod):
            if attr.startswith("_") or not is_salt_name(attr):
                continue
            val = getattr(mod, attr)
            if isinstance(val, dict):
                vals = list(val.values())
                assert len(set(vals)) == len(vals), (
                    f"salt dict {attr} has colliding values: {val}")
                continue
            if not isinstance(val, int):
                continue
            if attr == "PACKED_SECTION_FOLD_BASE":
                # registered through its BASE+s instances above
                assert val == FOLD_VALUES["PACKED_SECTION_FOLD_0"]
                continue
            assert val in registered, (
                f"salt constant {attr} = 0x{val:08X} is not registered "
                f"in tests/test_stream_spec.py (RESERVED_FOLDS or "
                f"AUX_SALTS) — register it with golden digests "
                f"(DESIGN.md §4)")


def test_klass_salt_pinned():
    """The per-klass region salts are part of the dist backward's key
    schedule — pinned like any other salt."""
    assert KLASS_SALT == KLASS_SALT_VALUES, (
        f"KLASS_SALT drifted: {KLASS_SALT} != spec'd {KLASS_SALT_VALUES}"
        f" — this re-keys the region mask streams (DESIGN.md §4)")


# ------------------------------------------------------------- aux salts
@pytest.mark.parametrize("name", AUX_NAMES)
def test_aux_salt_value_pinned(name):
    assert AUX_SALTS[name] == AUX_VALUES[name], (
        f"aux salt {name} changed: {AUX_SALTS[name]} != spec'd "
        f"{AUX_VALUES[name]} — this re-keys every draw folded under it "
        f"(DESIGN.md §4)")


def test_aux_salts_pairwise_distinct():
    for a, b in itertools.combinations(AUX_NAMES, 2):
        assert AUX_SALTS[a] != AUX_SALTS[b], (
            f"aux salts {a} and {b} collide at {AUX_SALTS[a]} — draws "
            f"folded under them off a shared parent key are identical")


@pytest.mark.parametrize("name", AUX_NAMES)
def test_golden_aux_first_u32(name):
    got = int(jax.random.bits(
        jax.random.fold_in(KEY, AUX_SALTS[name]), (4,), jnp.uint32)[0])
    assert got == GOLDEN_AUX_U32[name], (
        f"aux-salt stream for {name} drifted: first u32 is 0x{got:08X}, "
        f"spec'd 0x{GOLDEN_AUX_U32[name]:08X} — the derived key moved "
        f"(DESIGN.md §4)")


# ----------------------------------------------------------- derived keys
def test_derived_stream_keys_pairwise_disjoint():
    """fold_in(key, fold) gives pairwise-distinct key material — the
    fold constants separating in key space, not just in value."""
    data = {n: np.asarray(jax.random.key_data(
        jax.random.fold_in(KEY, f))) for n, f in RESERVED_FOLDS.items()}
    for a, b in itertools.combinations(FOLD_NAMES, 2):
        assert not np.array_equal(data[a], data[b]), (
            f"derived keys for folds {a} and {b} coincide — their "
            f"streams are identical")


# --------------------------------------------------------- golden digests
@pytest.mark.parametrize("name", FOLD_NAMES)
def test_golden_gain_first_u32(name):
    got = int(ota.stream_range_bits(
        ota.section_gain_key(KEY, RESERVED_FOLDS[name], 0), 0, 4)[0])
    assert got == GOLDEN_GAIN_U32[name], (
        f"gain stream for fold {name} drifted: first u32 is "
        f"0x{got:08X}, spec'd 0x{GOLDEN_GAIN_U32[name]:08X} — the "
        f"chunk-quantized threefry draw changed (DESIGN.md §4)")


@pytest.mark.parametrize("name", FOLD_NAMES)
def test_golden_noise_first_u32(name):
    got = int(ota.stream_range_bits(
        ota.section_noise_key(KEY, RESERVED_FOLDS[name]), 0, 4)[0])
    assert got == GOLDEN_NOISE_U32[name], (
        f"noise stream for fold {name} drifted: first u32 is "
        f"0x{got:08X}, spec'd 0x{GOLDEN_NOISE_U32[name]:08X} — the "
        f"chunk-quantized threefry draw changed (DESIGN.md §4)")


# ----------------------------------------------------- position rules
def test_chunk_slice_identity():
    """stream_range_bits(key, a, n) == whole-stream[a : a+n], including
    across a chunk boundary — the position-determinism slice rule that
    lets per-cluster streaming draws, per-region backward draws and
    whole-section oracle draws consume identical bits."""
    k = ota.section_gain_key(KEY, ota.PACKED_TAIL_FOLD, 1)
    length = ota.CHUNK + 640
    full = ota._chunked_stream(k, length)
    for start, n in [(0, 16), (ota.CHUNK - 8, 16), (ota.CHUNK, 128),
                     (513, 257), (length - 64, 64)]:
        part = ota.stream_range_bits(k, start, n)
        assert jnp.array_equal(part, full[start:start + n]), (
            f"stream_range_bits(start={start}, n={n}) != whole-stream "
            f"slice — the chunk-quantization slice rule broke")


def test_section_fold_schedule():
    """Trunk section s ⇒ PACKED_SECTION_FOLD_BASE + s; the ω̃ tail keeps
    PACKED_TAIL_FOLD in EVERY layout; the legacy two-section layout maps
    to the HEAD/TAIL pair (DESIGN.md §4, fold-after-coalescing rule)."""
    tmpl = {
        "final": {"w": jax.ShapeDtypeStruct((40, 8), jnp.float32)},
        "trunk": {"fc0": {"w": jax.ShapeDtypeStruct((30, 50), jnp.float32)},
                  "fc1": {"w": jax.ShapeDtypeStruct((50, 40), jnp.float32)}},
    }
    multi = packer_for(tmpl, tail="final", sections="toplevel")
    folds = ota.packed_section_folds(multi)
    assert folds[-1] == ota.PACKED_TAIL_FOLD, (
        f"ω̃ tail section fold is 0x{folds[-1]:08X}, not "
        f"PACKED_TAIL_FOLD — eq.-5 consumers would re-draw wrong masks")
    for i, f in enumerate(folds[:-1]):
        assert f == ota.PACKED_SECTION_FOLD_BASE + i, (
            f"trunk section {i} fold is 0x{f:08X}, spec'd BASE+{i} = "
            f"0x{ota.PACKED_SECTION_FOLD_BASE + i:08X}")
    legacy = packer_for(tmpl, tail="final")
    assert ota.packed_section_folds(legacy) == [
        ota.PACKED_HEAD_FOLD, ota.PACKED_TAIL_FOLD], (
        "legacy two-section layout no longer maps to HEAD/TAIL folds")


# --------------------------------------------- participation + sampling
def test_participation_subfolds_disjoint():
    """The dropout/blackout/straggler uniforms draw from sub-folds 0/1/2
    of participation_key(round_key) — pairwise distinct, and distinct
    from the channel key and the sample key of the same round."""
    pk = ota.participation_key(KEY)
    keys = {f"PART_FOLD/{i}": jax.random.fold_in(pk, i) for i in range(3)}
    keys["SIM_CHAN_FOLD"] = ota.sim_channel_key(KEY)
    keys["SAMPLE_FOLD"] = ota.sample_key(KEY)
    keys["NOISE_FOLD"] = ota.noise_key(KEY)
    data = {n: np.asarray(jax.random.key_data(k)) for n, k in keys.items()}
    for a, b in itertools.combinations(sorted(data), 2):
        assert not np.array_equal(data[a], data[b]), (
            f"stream keys {a} and {b} coincide — resampling one would "
            f"perturb the other's draws")


def test_sample_draw_golden():
    """The client-id draw is a pure function of the round key through
    SAMPLE_FOLD — golden-pinned so a re-keying shows up by name."""
    ids = ota.draw_client_sample(KEY, 2, 3, 7)
    assert ids.dtype == jnp.int32
    assert ids.tolist() == [[0, 6, 2], [5, 4, 6]], (
        f"SAMPLE_FOLD client-id draw drifted: {ids.tolist()} — the "
        f"sample stream was re-keyed (DESIGN.md §4)")
    assert bool(jnp.all((ids >= 0) & (ids < 7)))
