"""Section-streaming rounds (DESIGN.md §3.16): the sectioned engine's
equivalence and memory pins, its composition gates, and the kernel-level
cluster blocking it rides on.

Covers: bitwise equivalence of ``ota_aggregate_sectioned`` to the
client-folded engine (streaming=False) and to the cluster-scan streaming
engine (streaming=True) under every composed feature (faults via
live/n_eff, split layouts via max_section_rows); the peak-memory HLO
pins with positive controls (the packed engine's (C, P) slab, the
client-folded engine's (C, CHUNK) stream draw); the no-silent-inertness
refusals (HotaSim build guards, the distributed step's ota_streaming
rejection, ``apply_layout``'s named LayoutUnavailableError, the stale
disk-cache re-measure path, LayoutBudgetError); the C-axis-blocked
client kernel vs its unblocked form; the hardware-PRNG seed schedule
(``tpu_hw_seed`` collision-freedom and blocking invariance); and the
forced-4-device distributed program (slow marker).
"""
import functools
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_audit
from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.common.flatpack import TreePacker, packer_for
from repro.common import layout_tune as lt
from repro.core import ota
from repro.core.channel import channel_params
from repro.core.sim import HotaSim
from repro.kernels.ota_channel import kernel as K
from repro.models.model import build_model

C, N = 2, 2
SRC = str(Path(__file__).resolve().parent.parent / "src")


def _grad_tree(key, c, n, scale=1.0):
    ks = [jax.random.fold_in(key, i) for i in range(6)]
    return {
        "final": {"w": jax.random.normal(ks[0], (c, n, 40, 8)) * scale,
                  "b": jax.random.normal(ks[1], (c, n, 8)) * scale},
        "trunk": {"fc0": {"w": jax.random.normal(ks[2], (c, n, 30, 50)) * scale,
                          "b": jax.random.normal(ks[3], (c, n, 50)) * scale},
                  "fc1": {"w": jax.random.normal(ks[4], (c, n, 50, 40)) * scale,
                          "b": jax.random.normal(ks[5], (c, n, 40)) * scale}},
    }


def _template(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[2:], l.dtype),
                        tree)


def _setup(c=C, n=N, key=11, max_section_rows=0):
    fl = FLConfig(n_clusters=c, n_clients=n,
                  sigma2=tuple(0.5 + 0.5 * i for i in range(c)),
                  noise_std=0.7)
    chan = channel_params(fl)
    k = jax.random.PRNGKey(key)
    g = _grad_tree(jax.random.fold_in(k, 1), c, n)
    p = jax.random.uniform(jax.random.fold_in(k, 2), (c, n), jnp.float32,
                           0.5, 1.5)
    packer = packer_for(_template(g), tail="final", sections="toplevel",
                        max_section_rows=max_section_rows)
    return fl, chan, k, g, p, packer


@functools.lru_cache(maxsize=None)
def _jitted(c=C, n=N, msr=0):
    """One compile per (C, N, max_section_rows) topology, shared across
    tests (interpret-mode kernels re-dispatch eagerly otherwise)."""
    fl, chan, key, g, p, packer = _setup(c, n, max_section_rows=msr)

    def wrap(agg, faulted, **kw):
        if faulted:
            return jax.jit(lambda k, gg, pp, lv, ne: agg(
                k, gg, pp, chan, n, packer, live=lv, n_eff=ne, **kw))
        return jax.jit(lambda k, gg, pp: agg(k, gg, pp, chan, n, packer,
                                             **kw))

    return {
        "args": (key, g, p),
        "packer": packer,
        "chan": chan,
        "fold": wrap(ota.ota_aggregate_client_folded, False),
        "stream": wrap(ota.ota_aggregate_streaming, False),
        "sec": wrap(ota.ota_aggregate_sectioned, False),
        "sec_s": wrap(ota.ota_aggregate_sectioned, False, streaming=True),
        "fold_f": wrap(ota.ota_aggregate_client_folded, True),
        "stream_f": wrap(ota.ota_aggregate_streaming, True),
        "sec_f": wrap(ota.ota_aggregate_sectioned, True),
        "sec_sf": wrap(ota.ota_aggregate_sectioned, True, streaming=True),
    }


def _tree_equal(a, b, msg):
    for (ka, la), (_, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{msg} at {jax.tree_util.keystr(ka)}")


# ===================================================== engine equivalence

@pytest.mark.parametrize("msr", [0, 8])
def test_sectioned_matches_client_folded_bitwise(msr):
    """streaming=False: every per-leaf kernel call sees byte-identical
    inputs to the client-folded engine's, so the result is BIT-identical
    — not merely associativity-close. Holds on split layouts too (the
    fold schedule changes WITH the packer, identically for both)."""
    j = _jitted(msr=msr)
    _tree_equal(j["sec"](*j["args"]), j["fold"](*j["args"]),
                f"sectioned != client-folded (msr={msr})")


@pytest.mark.parametrize("msr", [0, 8])
def test_sectioned_streaming_matches_streaming_bitwise(msr):
    """streaming=True: the cluster scan nested inside each section
    accumulates every leaf in the same cluster order as the §3.15
    engine — bit-identical to ota_aggregate_streaming."""
    j = _jitted(msr=msr)
    _tree_equal(j["sec_s"](*j["args"]), j["stream"](*j["args"]),
                f"sectioned(streaming) != streaming (msr={msr})")


def test_sectioned_matches_under_faults():
    """Composed partial participation: live-masked clusters and the
    traced n_eff denominator flow through the section schedule
    unchanged — still bit-identical to the respective engines."""
    j = _jitted()
    live = jnp.asarray([1.0, 0.0])
    n_eff = jnp.float32(1.5)
    _tree_equal(j["sec_f"](*j["args"], live, n_eff),
                j["fold_f"](*j["args"], live, n_eff),
                "faulted sectioned != faulted client-folded")
    _tree_equal(j["sec_sf"](*j["args"], live, n_eff),
                j["stream_f"](*j["args"], live, n_eff),
                "faulted sectioned(streaming) != faulted streaming")


def test_sectioned_rejects_bad_bits_mode():
    fl, chan, key, g, p, packer = _setup()
    with pytest.raises(ValueError):
        ota.ota_aggregate_sectioned(key, g, p, chan, N, packer,
                                    bits_mode="nope")


# ======================================================== peak-memory HLO

def _lower(agg, setup, **kw):
    fl, chan, key, g, p, packer = setup
    return jax.jit(lambda k, gg, pp: agg(
        k, gg, pp, chan, N, packer, **kw)).lower(
            key, g, p).compile().as_text()


def test_sectioned_hlo_no_full_slab():
    """The §3.16 pin: the compiled sectioned round holds no (P,)-sized
    or (C, P)-sized f32/u32 buffer — peak live streams are one section.
    Positive control: the PACKED engine materializes the f32[C, P] slab
    (so this pin cannot rot into vacuity)."""
    setup = _setup()
    fl, chan, key, g, p, packer = setup
    P = packer.size
    for kw in ({}, {"streaming": True}):
        hlo = _lower(ota.ota_aggregate_sectioned, setup, **kw)
        hlo_audit.assert_hlo_pins(
            hlo, hlo_audit.no_slab_pins(C, P),
            context=f"sectioned round {kw} — per-section peak (§3.16)")
    wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p, l), g)
    hlo_packed = jax.jit(lambda k, w: ota.ota_aggregate_packed(
        k, w, chan, N, packer)).lower(key, wg).compile().as_text()
    hlo_audit.assert_hlo_pins(
        hlo_packed,
        [hlo_audit.require_buffer((C, P), dtypes=("f32",),
                                  note="the packed engine's (C, P) slab")],
        context="packed-engine positive control")


def test_sectioned_streaming_hlo_holds_one_cluster_one_section():
    """Composed with the cluster scan, the peak drops further: no
    (C, ·) stream buffer at ANY size — per-section AND per-cluster.
    Positive control: the all-clusters engines (client-folded and
    sectioned streaming=False) draw the (C, CHUNK) chunked stream."""
    setup = _setup()
    _, chan, key, g, p, packer = setup
    lengths = sorted({sec.length for sec in packer.sections})
    hlo_s = _lower(ota.ota_aggregate_sectioned, setup, streaming=True)
    hlo_audit.assert_hlo_pins(
        hlo_s,
        hlo_audit.no_cluster_stream_pins(
            C, lengths + [packer.size, ota.CHUNK]),
        context="sectioned(streaming=True) — one-cluster peak (§3.16)")
    for agg, kw in ((ota.ota_aggregate_client_folded, {}),
                    (ota.ota_aggregate_sectioned, {})):
        hlo_c = _lower(agg, setup, **kw)
        hlo_audit.assert_hlo_pins(
            hlo_c, hlo_audit.cluster_chunk_stream_pin(C, ota.CHUNK),
            context=f"all-clusters positive control ({agg.__name__})")


# ================================================== no-silent-inertness

def _mk_model():
    return build_model(ModelConfig(family="mlp"))


def test_hotasim_rejects_sectioned_without_slab_engine():
    fl = FLConfig(n_clusters=C, n_clients=N, ota_sectioned=True,
                  use_pallas_ota=False)
    with pytest.raises(ValueError, match="ota_sectioned"):
        HotaSim(_mk_model(), fl, TrainConfig(lr=3e-4), [4, 4])


def test_hotasim_rejects_sectioned_on_two_section_layout():
    fl = FLConfig(n_clusters=C, n_clients=N, ota_sectioned=True,
                  ota_sections="tail")
    with pytest.raises(ValueError, match="multi-section"):
        HotaSim(_mk_model(), fl, TrainConfig(lr=3e-4), [4, 4])


def test_hotasim_rejects_section_split_without_slab_engine():
    fl = FLConfig(n_clusters=C, n_clients=N, max_section_rows=8,
                  use_pallas_ota=False)
    with pytest.raises(ValueError, match="max_section_rows"):
        HotaSim(_mk_model(), fl, TrainConfig(lr=3e-4), [4, 4])


def test_sectioned_sim_round_runs_and_matches():
    """End-to-end sim: one FGN round under ota_sectioned tracks the
    default engine's round (same streams, float-level agreement)."""
    def round_metrics(**kw):
        fl = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1,
                      sigma2=(0.5, 1.0), **kw)
        sim = HotaSim(_mk_model(), fl, TrainConfig(lr=3e-4), [4, 4])
        state = sim.init(jax.random.PRNGKey(0))
        k = jax.random.PRNGKey(1)
        x = jax.random.normal(jax.random.fold_in(k, 0), (C, N, 4, 256))
        y = jax.random.randint(jax.random.fold_in(k, 1), (C, N, 4), 0, 4)
        state, m = sim.step(state, x, y, jax.random.fold_in(k, 2))
        return state.omega, m

    om_a, _ = round_metrics()
    om_b, _ = round_metrics(ota_sectioned=True)
    # a split layout RE-KEYS the streams (fold = BASE + section index),
    # so the msr run compares against the full-slab engine on the SAME
    # split layout — the streaming composition changes only the cluster
    # reduction order (associativity-level)
    om_c, _ = round_metrics(max_section_rows=8)
    om_d, _ = round_metrics(ota_sectioned=True, ota_streaming=True,
                            max_section_rows=8)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), om_a, om_b)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), om_c, om_d)


# ============================================= layout autotuner refusals

def test_apply_layout_refuses_unavailable_engine():
    fl = FLConfig(n_clusters=C, n_clients=N)
    bad = [
        lt.LayoutChoice("warp", "toplevel", 0),            # unknown engine
        lt.LayoutChoice("sectioned", "tail", 0),           # two-section
        lt.LayoutChoice("perleaf", "toplevel", 0, 8),      # split sans slab
        lt.LayoutChoice("slab", "toplevel", 16, 8),        # max < min
        lt.LayoutChoice("slab", "toplevel", 0, -1),        # negative cap
    ]
    for choice in bad:
        with pytest.raises(lt.LayoutUnavailableError):
            lt.apply_layout(fl, choice)


def test_from_metadata_validates_availability():
    with pytest.raises(lt.LayoutUnavailableError):
        lt.LayoutChoice.from_metadata(
            {"engine": "warp", "sections": "toplevel",
             "min_section_rows": 0})
    # the max_section_rows key is optional — old manifests stay valid
    c = lt.LayoutChoice.from_metadata(
        {"engine": "slab", "sections": "toplevel", "min_section_rows": 0})
    assert c.max_section_rows == 0
    assert "max_section_rows" not in c.to_metadata()
    c2 = lt.LayoutChoice("sectioned", "toplevel", 0, 8)
    assert lt.LayoutChoice.from_metadata(c2.to_metadata()) == c2


def test_tune_layout_remeasures_stale_cache(tmp_path):
    """A disk-cache entry naming an engine the current gates cannot run
    is re-measured, not crashed on and not honored (satellite: stale
    LayoutChoice refusal)."""
    template = _template(_grad_tree(jax.random.PRNGKey(0), C, N))
    thresholds = (0,)
    h = lt.template_hash(template, C, N, thresholds, False, None)
    cache = tmp_path / "layout_cache.json"
    cache.write_text(json.dumps(
        {h: {"engine": "warp", "sections": "toplevel",
             "min_section_rows": 0}}))
    lt._TUNE_CACHE.clear()
    choice = lt.tune_layout(template, C, N, thresholds=thresholds,
                            iters=1, include_perleaf=False,
                            cache_path=str(cache))
    assert choice.engine in lt.ENGINES
    # the re-measured winner replaced the stale entry on disk
    fresh = json.loads(cache.read_text())[h]
    assert fresh["engine"] in lt.ENGINES
    lt._TUNE_CACHE.clear()


def test_calibrate_layout_budget_error():
    template = _template(_grad_tree(jax.random.PRNGKey(0), C, N))
    with pytest.raises(lt.LayoutBudgetError):
        lt.calibrate_layout(template, C, N, thresholds=(0,), iters=1,
                            include_perleaf=False, memory_budget_bytes=1)


def test_estimate_peak_slab_bytes_ordering():
    """The coarse working-set model ranks engines the way the §3.16
    scheduling argument says it must: per-leaf ≤ sectioned ≤ full slab,
    and a budget split shrinks the sectioned peak further."""
    template = _template(_grad_tree(jax.random.PRNGKey(0), C, N))
    est = lambda ch: lt.estimate_peak_slab_bytes(template, ch, C, N)
    slab = est(lt.LayoutChoice("slab", "toplevel", 0))
    sec = est(lt.LayoutChoice("sectioned", "toplevel", 0))
    leaf = est(lt.LayoutChoice("perleaf", "toplevel", 0))
    split = est(lt.LayoutChoice("sectioned", "toplevel", 0, 8))
    assert leaf <= sec < slab
    assert split <= sec
    rows = lt._budget_section_rows(C, N, slab)
    assert rows >= 1
    assert lt._budget_section_rows(C, N, 1) == 1


# ================================================ kernel cluster blocking

def _client_kernel_inputs(c=5, n=2, rows=16, key=3):
    k = jax.random.PRNGKey(key)
    x = jax.random.normal(jax.random.fold_in(k, 0),
                          (c, n, rows, K.LANE), jnp.float32)
    bits = jax.random.bits(jax.random.fold_in(k, 1),
                           (c, rows, K.LANE), jnp.uint32)
    nbits = jax.random.bits(jax.random.fold_in(k, 2),
                            (rows, K.LANE), jnp.uint32)
    sig = jnp.linspace(0.4, 1.6, c, dtype=jnp.float32)
    p = jax.random.uniform(jax.random.fold_in(k, 4), (c, n), jnp.float32,
                           0.5, 1.5)
    live = jnp.ones((c,), jnp.float32).at[1].set(0.0)
    params = jnp.concatenate([
        sig, p.reshape(c * n),
        jnp.asarray([0.3, 0.7, 1.0], jnp.float32),      # H_th, z_std, on
        live, jnp.asarray([float(n)], jnp.float32),
    ]).reshape(1, c * (n + 2) + 4)
    return x, bits, nbits, params


@pytest.mark.parametrize("cb", [1, 2, 3])
def test_blocked_client_kernel_matches_unblocked(cb):
    """C-axis blocking (scratch accumulation over cluster blocks,
    including a live-masked cluster and a padded tail block) equals the
    single-block kernel to fusion level — same float order, so the
    tolerance is ulps, not associativity."""
    x, bits, nbits, params = _client_kernel_inputs(c=5, n=2)
    run = lambda blk: K.ota_aggregate_client_pallas(
        x, bits, nbits, params, n_clients=2, interpret=True,
        cluster_block=blk)
    ref = run(0)       # interpret auto-picks cb=C: the unblocked kernel
    np.testing.assert_allclose(np.asarray(run(cb)), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_auto_cluster_block_fits_budget():
    """The auto block size always fits the VMEM model and never blocks
    when the whole cluster axis fits."""
    assert K._client_cluster_block(4, 2, interpret=True) == 4
    unit = K.SUBLANE * K.LANE * 4
    for c, n in [(4, 2), (64, 8), (1024, 32)]:
        cb = K._client_cluster_block(c, n, interpret=False)
        assert 1 <= cb <= c
        assert cb == c or (cb * (n + 1) + 2) * unit <= K.VMEM_BUDGET_BYTES


def test_client_params_blocked_layout():
    """The re-tiled per-block params rows carry the same (σ², p, scalars,
    live, N_eff) layout with live=0 padding on the tail block."""
    _, _, _, params = _client_kernel_inputs(c=5, n=2)
    cb, n_cb = 2, 3
    rows = K._client_params_blocked(params, 5, 2, cb, n_cb)
    assert rows.shape == (n_cb, cb * (2 + 2) + 4)
    sig = np.asarray(params[0, :5])
    live = np.asarray(params[0, 5 + 10 + 3:5 + 10 + 3 + 5])
    got_sig = np.asarray(rows[:, :cb]).reshape(-1)
    got_live = np.asarray(rows[:, cb * 3 + 3:cb * 3 + 3 + cb]).reshape(-1)
    np.testing.assert_array_equal(got_sig[:5], sig)
    np.testing.assert_array_equal(got_sig[5:], 0.0)
    np.testing.assert_array_equal(got_live[:5], live)
    np.testing.assert_array_equal(got_live[5:], 0.0)    # padded dead
    np.testing.assert_array_equal(np.asarray(rows[:, -1]), 2.0)


# ================================================ hardware-PRNG schedule

def test_tpu_hw_seed_schedule_collision_free():
    """The compiled TPU branch's per-(cluster, chunk) seeds are distinct
    across the whole grid — and keyed on GLOBAL cluster indices, so
    C-axis blocking enumerates the identical seed set in any block
    shape (the blocking-invariance half of the §3.16 kernel rule)."""
    key2 = jnp.asarray([0xDEADBEEF, 0x12345678], jnp.uint32)
    CC, II = 64, 256
    ls, iis = np.meshgrid(np.arange(CC), np.arange(II), indexing="ij")
    seeds = np.asarray(jax.vmap(
        lambda l, i: K.tpu_hw_seed(key2, l, i))(
            jnp.asarray(ls.ravel(), jnp.uint32),
            jnp.asarray(iis.ravel(), jnp.uint32)))
    assert len(np.unique(seeds)) == CC * II
    # AWGN stream (l=None) is the l-free base schedule — same arithmetic
    # as l=0; disjointness from the gain streams comes from its own key
    awgn = np.asarray(jax.vmap(
        lambda i: K.tpu_hw_seed(key2, None, i))(
            jnp.arange(II, dtype=jnp.uint32)))
    np.testing.assert_array_equal(awgn, seeds.reshape(CC, II)[0])
    # blocked enumeration (any cb) covers the same global seed set
    cb = 5
    blocked = []
    for j in range((CC + cb - 1) // cb):
        for l_loc in range(cb):
            l = j * cb + l_loc
            if l < CC:
                blocked.append(int(K.tpu_hw_seed(
                    key2, jnp.uint32(l), jnp.uint32(0))))
    np.testing.assert_array_equal(np.sort(np.asarray(blocked)),
                                  np.sort(seeds.reshape(CC, II)[:, 0]))


def test_tpu_fused_kernel_traces():
    """The compiled-TPU fused kernel (hardware PRNG, C-blocked grid) is
    structurally valid: abstract evaluation on any backend succeeds and
    yields the section-slab output shape. (Execution needs a TPU; this
    pins that the branch cannot rot into a trace error.)"""
    c, rows = 3, 2 * K.CHUNK_ROWS
    wg = jax.ShapeDtypeStruct((c, rows, K.LANE), jnp.float32)
    keys = jax.ShapeDtypeStruct((2, 2), jnp.uint32)
    params = jax.ShapeDtypeStruct((1, c + 3), jnp.float32)
    out = jax.eval_shape(
        lambda w, k, pr: K.ota_aggregate_fused_pallas(
            w, k, pr, n_clients=2, interpret=False), wg, keys, params)
    assert out.shape == (rows, K.LANE) and out.dtype == jnp.float32


# =============================================== distributed (slow path)

@pytest.mark.slow
def test_dist_sectioned_program():
    """Forced-4-device program: sectioned distributed backward bitwise
    vs full-slab under count_mode x max_section_rows, the jnp oracle,
    the end-to-end sectioned train step, and the ota_streaming
    rejection. See tests/dist_programs/dist_sectioned.py."""
    prog = Path(__file__).resolve().parent / "dist_programs" / \
        "dist_sectioned.py"
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": "/tmp"}
    r = subprocess.run([sys.executable, str(prog)], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DIST_SECTIONED_OK" in r.stdout, r.stdout
