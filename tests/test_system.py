"""End-to-end behaviour tests for the paper's system.

The headline claims, at test scale (C=2, N=3, short horizon):
 1. HOTA-FedGradNorm training converges under the noisy fading MAC.
 2. Dynamic weighting responds to task asymmetry (weights diverge from 1).
 3. A degraded channel (low σ²) sparsifies that cluster's contribution,
    and FedGradNorm reacts while equal weighting cannot.
Full-scale reproductions (C=10, 250+ steps) live in benchmarks/fig*.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.sim import HotaSim
from repro.data.federated import FederatedBatcher
from repro.data.radcom import (
    N_CLASSES, RadComConfig, TASKS, client_partition, make_radcom_dataset,
)
from repro.models.model import build_model


def _run(weighting, sigma2=(), steps=30, seed=0, noise=1.0):
    data = make_radcom_dataset(RadComConfig(n_points=9000))
    parts = client_partition(data, 2, 3, seed=seed)
    batcher = FederatedBatcher(parts, 24, seed=seed)
    n_cls = [N_CLASSES[TASKS[i % 3]] for i in range(3)]
    model = build_model(ModelConfig(family="mlp"))
    fl = FLConfig(n_clusters=2, n_clients=3, weighting=weighting,
                  sigma2=tuple(sigma2), noise_std=noise)
    sim = HotaSim(model, fl, TrainConfig(lr=3e-4), n_cls)
    state = sim.init(jax.random.PRNGKey(seed))
    losses, ps = [], []
    for s in range(steps):
        x, y = batcher.next_stacked()
        state, m = sim.step(state, jnp.asarray(x), jnp.asarray(y),
                            jax.random.PRNGKey(1000 + s))
        losses.append(np.asarray(m["loss"]))
        ps.append(np.asarray(m["p"]))
    return np.stack(losses), np.stack(ps)


@pytest.mark.slow
def test_hota_fgn_converges_under_noisy_mac():
    losses, ps = _run("fedgradnorm", steps=40)
    assert np.isfinite(losses).all()
    assert losses[-8:].mean() < losses[:8].mean()
    # weights adapt away from uniform but stay normalized
    np.testing.assert_allclose(ps[-1].sum(axis=1), 3.0, rtol=1e-4)
    assert np.abs(ps[-1] - 1.0).max() > 1e-3


@pytest.mark.slow
def test_equal_weighting_static():
    losses, ps = _run("equal", steps=10)
    np.testing.assert_allclose(ps, 1.0)
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_bad_channel_sparsifies_and_fgn_reacts():
    """σ₁² ≪ H_th: cluster 0 passes almost nothing over the MAC; training
    still converges on the healthy cluster's contributions and FedGradNorm
    keeps adapting — the channel-awareness the paper claims."""
    losses, ps = _run("fedgradnorm", sigma2=(0.01, 1.0), steps=30)
    assert np.isfinite(losses).all()
    dev1 = np.abs(ps[-1, 1] - 1.0).max()
    assert dev1 > 0
    assert losses[-5:].mean() < losses[:5].mean() + 0.05
