"""Flat-packed OTA aggregation vs the per-leaf oracle (shared bit stream),
plus the paper's edge cases routed through the fused kernel and the PRNG
stream-disjointness pins (noise vs cluster fold-in domains)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import FLConfig
from repro.common.flatpack import packer_for
from repro.core import ota
from repro.core.channel import channel_params, stack_channel_params
from repro.kernels import ota_aggregate, ota_aggregate_reference
from repro.kernels.ota_channel.ref import bits_to_gaussian, bits_to_mask


def _wg_tree(key, C, scale=1.0):
    """A per-cluster weighted-grad pytree in the sim's omega layout."""
    ks = [jax.random.fold_in(key, i) for i in range(4)]
    return {
        "final": {"w": jax.random.normal(ks[0], (C, 40, 8)) * scale,
                  "b": jax.random.normal(ks[1], (C, 8)) * scale},
        "trunk": {"fc0": {"w": jax.random.normal(ks[2], (C, 30, 50)) * scale,
                          "b": jax.random.normal(ks[3], (C, 50)) * scale}},
    }


def _template(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                        tree)


# ---------------------------------------------------------------- packed vs
# per-leaf oracle on a SHARED bit stream: the kernel's estimate must equal
# running eqs. 8-10 leaf-by-leaf with the masks/noise decoded from the same
# bits (ota_aggregate_leaf is the seed implementation, kept as oracle).
@pytest.mark.parametrize("C,sigmas", [(2, (1.0, 0.25)), (4, (0.5,)),
                                      (10, (0.25, 0.5, 1.0, 2.0))])
def test_packed_matches_per_leaf_oracle(C, sigmas):
    fl = FLConfig(n_clusters=C, n_clients=3, sigma2=sigmas, noise_std=0.7)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(42)
    wg = _wg_tree(jax.random.fold_in(key, 1), C)
    packer = packer_for(_template(wg), tail="final")

    ghat = ota.ota_aggregate_packed(key, wg, chan, fl.n_clients, packer)

    # oracle: same bits -> per-leaf masks/noise -> seed ota_aggregate_leaf
    bits = ota.packed_gain_bits(key, packer, C)              # (C, P)
    nbits = ota.packed_noise_bits(key, packer)
    sig = chan.sigma2.reshape(C, 1)
    masks_slab = bits_to_mask(bits, sig, chan.h_threshold, chan.ota_on)
    noise_slab = (bits_to_gaussian(nbits, 1.0) * chan.noise_std
                  * chan.ota_on)
    mask_tree = packer.unpack(masks_slab.astype(jnp.float32))
    noise_tree = packer.unpack(noise_slab)
    oracle = jax.tree.map(
        lambda w, m, z: ota.ota_aggregate_leaf(w, m > 0.5, z, fl.n_clients),
        wg, mask_tree, noise_tree)

    for a, b in zip(jax.tree.leaves(ghat), jax.tree.leaves(oracle)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(C=st.integers(1, 6), n=st.integers(3, 400), seed=st.integers(0, 99),
       noise=st.floats(0.0, 3.0))
def test_packed_slab_kernel_matches_ref_property(C, n, seed, noise):
    """ota_aggregate (Pallas) == ota_aggregate_reference (jnp) on random
    lane-aligned slabs — the kernel-level contract."""
    key = jax.random.PRNGKey(seed)
    p = 1024 * (-(-n // 1024))
    wg = jax.random.normal(key, (C, p))
    bits = jax.random.bits(jax.random.fold_in(key, 1), (C, p), jnp.uint32)
    nbits = jax.random.bits(jax.random.fold_in(key, 2), (p,), jnp.uint32)
    sigma2 = jnp.linspace(0.25, 2.0, C)
    a = ota_aggregate(wg, bits, nbits, sigma2, 0.032, noise, 1.0, 3)
    b = ota_aggregate_reference(wg, bits, nbits, sigma2, 0.032, noise, 1.0, 3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_final_masks_are_tail_slice_of_round_draw():
    """final_layer_masks_packed must reproduce, bit-for-bit, the masks the
    full aggregation applies to the ω̃ tail (eq. 5 == transmission)."""
    C = 3
    fl = FLConfig(n_clusters=C, n_clients=2, sigma2=(0.5, 1.0, 2.0))
    chan = channel_params(fl)
    key = jax.random.PRNGKey(7)
    wg = _wg_tree(jax.random.fold_in(key, 1), C)
    packer = packer_for(_template(wg), tail="final")

    fmasks = ota.final_layer_masks_packed(key, chan, packer)

    bits = ota.packed_gain_bits(key, packer, C)
    sig = chan.sigma2.reshape(C, 1)
    full_masks = bits_to_mask(bits, sig, chan.h_threshold, chan.ota_on)
    tail_masks = packer.unpack_tail(packer.tail_slice(full_masks))

    for a, b in zip(jax.tree.leaves(fmasks), jax.tree.leaves(tail_masks)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # masks are non-trivial at the default threshold
    rate = float(jnp.mean(jnp.concatenate(
        [m.reshape(-1).astype(jnp.float32)
         for m in jax.tree.leaves(fmasks)])))
    assert 0.5 < rate < 1.0


def test_packed_all_blocked_is_exact_zero():
    """σ² → 0 with H_th > 0: |M_k| = 0 everywhere, so ĝ must be exactly 0
    on every leaf — never noise/(cnt·N), never NaN — through the kernel."""
    C = 3
    fl = FLConfig(n_clusters=C, n_clients=2, h_threshold=0.5, noise_std=5.0,
                  sigma2=(1e-14,))
    chan = channel_params(fl)
    wg = jax.tree.map(lambda l: jnp.full_like(l, 1e6),
                      _wg_tree(jax.random.PRNGKey(0), C))
    packer = packer_for(_template(wg), tail="final")
    ghat = ota.ota_aggregate_packed(jax.random.PRNGKey(11), wg, chan,
                                    fl.n_clients, packer)
    for leaf in jax.tree.leaves(ghat):
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        np.testing.assert_array_equal(arr, np.zeros_like(arr))


def test_packed_ota_off_is_plain_weighted_mean():
    """ota=False through the kernel: traced gate forces all-pass masks and
    zero AWGN -> ĝ = Σ_l wg_l / (C·N) exactly (error-free baseline)."""
    C, N = 4, 3
    fl = FLConfig(n_clusters=C, n_clients=N, noise_std=7.0, ota=False)
    chan = channel_params(fl)
    wg = _wg_tree(jax.random.PRNGKey(5), C)
    packer = packer_for(_template(wg), tail="final")
    ghat = ota.ota_aggregate_packed(jax.random.PRNGKey(2), wg, chan, N,
                                    packer)
    for g, w in zip(jax.tree.leaves(ghat), jax.tree.leaves(wg)):
        ref = np.asarray(w).sum(axis=0) / (C * N)
        np.testing.assert_allclose(np.asarray(g), ref, rtol=1e-6, atol=1e-7)


def test_supplied_bits_mode_identical_to_fused():
    """bits_mode="supplied" (ScenarioBank's vmap-hoisted draw) must
    reproduce the fused in-kernel stream value-for-value."""
    C = 3
    fl = FLConfig(n_clusters=C, n_clients=2, sigma2=(0.5, 1.0, 2.0),
                  noise_std=0.8)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(21)
    wg = _wg_tree(jax.random.fold_in(key, 1), C)
    packer = packer_for(_template(wg), tail="final")
    a = ota.ota_aggregate_packed(key, wg, chan, 2, packer)
    b = ota.ota_aggregate_packed(key, wg, chan, 2, packer,
                                 bits_mode="supplied")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


def test_packed_composes_with_scenario_vmap():
    """The packed path under a (S,)-batched ChannelParams bank (the
    ScenarioBank contract): vmap over chan, shared key/wg (CRN)."""
    C, N = 2, 3
    base = FLConfig(n_clusters=C, n_clients=N)
    bank = stack_channel_params([
        channel_params(base),
        channel_params(FLConfig(n_clusters=C, n_clients=N,
                                sigma2=(0.05, 1.0))),
        channel_params(FLConfig(n_clusters=C, n_clients=N, ota=False)),
    ])
    key = jax.random.PRNGKey(3)
    wg = _wg_tree(jax.random.fold_in(key, 1), C)
    packer = packer_for(_template(wg), tail="final")

    banked = jax.vmap(
        lambda ch: ota.ota_aggregate_packed(key, wg, ch, N, packer))(bank)
    for s in range(3):
        one = ota.ota_aggregate_packed(
            key, wg, jax.tree.map(lambda x: x[s], bank), N, packer)
        for a, b in zip(jax.tree.leaves(one),
                        jax.tree.leaves(jax.tree.map(lambda x: x[s], banked))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


def test_sim_packed_equals_per_leaf_when_ota_off():
    """End-to-end: with the channel off both sim paths are the exact same
    weighted mean, so one step from identical init must match leaf-for-leaf
    (the only scenario where the two PRNG schemes cannot differ). The
    packed path keeps its PS Adam moments as one flat slab
    (repro.optim.adam.SlabAdamState), so the optimizer states are
    compared through ``tree_to_slab`` rather than leaf-zipped."""
    import dataclasses
    from repro.common.config import ModelConfig, TrainConfig
    from repro.core.sim import HotaSim
    from repro.optim.adam import tree_to_slab
    C, N = 2, 2
    model_cfg = ModelConfig(family="mlp")
    from repro.models.model import build_model
    model = build_model(model_cfg)
    base = FLConfig(n_clusters=C, n_clients=N, ota=False, noise_std=3.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (C, N, 8, 256))
    y = jax.random.randint(jax.random.PRNGKey(2), (C, N, 8), 0, 4)
    outs = []
    for packed in (True, False):
        fl = dataclasses.replace(base, use_pallas_ota=packed)
        sim = HotaSim(model, fl, TrainConfig(lr=3e-4), [4, 4])
        st_ = sim.init(jax.random.PRNGKey(0))
        st_, m = sim.step(st_, x, y, jax.random.PRNGKey(9))
        outs.append((st_, m))
    (st_p, m_p), (st_l, m_l) = outs
    for field in ("omega", "heads", "p", "head_opt", "fgn", "f0", "step"):
        for a, b in zip(jax.tree.leaves(getattr(st_p, field)),
                        jax.tree.leaves(getattr(st_l, field))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=field)
    for a, b in zip(jax.tree.leaves(m_p), jax.tree.leaves(m_l)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert int(st_p.ps_opt.step) == int(st_l.ps_opt.step)
    for slab, tree in ((st_p.ps_opt.mu, st_l.ps_opt.mu),
                       (st_p.ps_opt.nu, st_l.ps_opt.nu)):
        np.testing.assert_allclose(np.asarray(slab),
                                   np.asarray(tree_to_slab(tree)),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------- PRNG pins
def _key_data(k):
    return tuple(np.asarray(jax.random.key_data(k)).tolist()
                 if hasattr(jax.random, "key_data")
                 else np.asarray(k).tolist())


def test_noise_key_disjoint_from_cluster_keys():
    """The old noise fold (999) collided with cluster_key(ks, 999); the new
    NOISE_FOLD domain sits above any reachable cluster index."""
    ks = ota.leaf_key(jax.random.PRNGKey(0), 0)
    nk = _key_data(ota.noise_key(ks))
    for c in (0, 1, 998, 999, 1000, 4095):
        assert _key_data(ota.cluster_key(ks, c)) != nk
    assert ota.NOISE_FOLD == 0x7FFFFFFF
    # the packed section folds live in the same reserved range
    assert ota.PACKED_HEAD_FOLD > 0x7FFF0000
    assert ota.PACKED_TAIL_FOLD > 0x7FFF0000


def test_noise_stream_pinned():
    """Pin the per-leaf noise stream to the NOISE_FOLD derivation so future
    refactors can't silently shift every figure's AWGN draws."""
    fl = FLConfig(n_clusters=2, n_clients=1, h_threshold=0.0, noise_std=1.0,
                  use_pallas_ota=False)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(4)
    wg = {"w": jnp.zeros((2, 64))}       # all-pass masks, zero signal
    ghat = ota.ota_aggregate_tree(key, wg, chan, 1)
    ks = ota.leaf_key(key, 0)
    expected = jax.random.normal(
        jax.random.fold_in(ks, ota.NOISE_FOLD), (64,)) / 2.0
    np.testing.assert_allclose(np.asarray(ghat["w"]), np.asarray(expected),
                               rtol=1e-6)
