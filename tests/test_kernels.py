"""Per-kernel shape/dtype sweeps asserting allclose against the pure-jnp
oracles (interpret mode on CPU), plus hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (
    flash_attention, flash_attention_reference,
    masked_gradnorm, masked_gradnorm_reference,
    ota_channel, ota_channel_reference,
)


# ---------------------------------------------------------------- ota_channel
@pytest.mark.parametrize("shape", [(100,), (8, 128), (2048,), (3, 17, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_channel_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    key = jax.random.PRNGKey(7)
    o1, m1 = ota_channel(x, key, 1.0, 0.032)
    o2, m2 = ota_channel_reference(x, key, 1.0, 0.032)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5000), sigma2=st.floats(0.25, 2.0),
       seed=st.integers(0, 99))
def test_ota_channel_property(n, sigma2, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    key = jax.random.PRNGKey(seed + 1)
    o1, m1 = ota_channel(x, key, sigma2, 0.032)
    o2, m2 = ota_channel_reference(x, key, sigma2, 0.032)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5)
    # masked entries are exactly zeroed; unmasked pass through unchanged
    np.testing.assert_array_equal(np.asarray(o1[m1 < 0.5]), 0.0)
    np.testing.assert_allclose(np.asarray(o1[m1 > 0.5]),
                               np.asarray(x[m1 > 0.5]), rtol=1e-6)


# ------------------------------------------------------------ masked_gradnorm
# impl="pallas" forces the tiled kernel (interpret mode on CPU) — off-TPU
# the wrapper dispatches to its jnp reference by default, so the kernel
# itself would silently stop being exercised without the override.
@pytest.mark.parametrize("t,p", [(1, 100), (3, 500), (8, 4096), (16, 10000),
                                 (5, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_gradnorm_matches_ref(t, p, dtype):
    g = jax.random.normal(jax.random.PRNGKey(1), (t, p)).astype(dtype)
    m = jax.random.uniform(jax.random.PRNGKey(2), (p,)) > 0.3
    n1 = masked_gradnorm(g, m, impl="pallas")
    n2 = masked_gradnorm_reference(g, m)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2),
                               rtol=3e-3 if dtype == jnp.bfloat16 else 1e-5)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 12), p=st.integers(1, 3000), seed=st.integers(0, 99))
def test_masked_gradnorm_property(t, p, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (t, p))
    m = jax.random.uniform(jax.random.PRNGKey(seed + 1), (p,)) > 0.5
    n1 = masked_gradnorm(g, m, impl="pallas")
    n2 = masked_gradnorm_reference(g, m)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=2e-5,
                               atol=1e-6)


def test_masked_gradnorm_dispatch_off_tpu():
    """Off-TPU the default dispatch is the jnp reference (the
    interpret-mode pallas_call is ~28x slower for identical values —
    BENCH_kernels.json); both impls agree and the override still forces
    the kernel."""
    from repro.kernels.slab import on_tpu
    g = jax.random.normal(jax.random.PRNGKey(3), (6, 2000))
    m = jax.random.uniform(jax.random.PRNGKey(4), (2000,)) > 0.4
    default = masked_gradnorm(g, m)
    ref = masked_gradnorm_reference(g, m)
    if not on_tpu():  # default == jnp dispatch: bit-identical to the ref
        np.testing.assert_array_equal(np.asarray(default), np.asarray(ref))
    forced = masked_gradnorm(g, m, impl="pallas")
    np.testing.assert_allclose(np.asarray(forced), np.asarray(ref),
                               rtol=2e-5, atol=1e-6)


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("b,s,h,kv,d,w", [
    (2, 256, 4, 2, 64, None),
    (1, 512, 4, 4, 128, 128),
    (2, 256, 8, 2, 96, 64),
    (1, 128, 2, 1, 32, None),
])
def test_flash_attention_matches_ref(b, s, h, kv, d, w):
    q = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, kv, d), jnp.float32)
    o1 = flash_attention(q, k, v, window=w, block_q=128, block_kv=128)
    o2 = flash_attention_reference(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    b, s, h, kv, d = 1, 256, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, kv, d)).astype(dtype)
    o1 = flash_attention(q, k, v, block_q=128, block_kv=128)
    o2 = flash_attention_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(
    sq_blocks=st.integers(1, 3),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    d=st.sampled_from([32, 64]),
    window=st.sampled_from([None, 64]),
    seed=st.integers(0, 50),
)
def test_flash_attention_property(sq_blocks, heads, d, window, seed):
    h, kv = heads
    s = 64 * sq_blocks
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, s, kv, d))
    o1 = flash_attention(q, k, v, window=window, block_q=64, block_kv=64)
    o2 = flash_attention_reference(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)
